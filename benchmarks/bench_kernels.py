"""Per-kernel microbenchmarks: interpret-mode wall time + allclose vs the
pure-jnp oracle (correctness gate doubles as the perf row)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.kernels.flash_attention import ops as fa
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.decode_attention import ops as da
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.grouped_gemm import ops as gg
from repro.kernels.grouped_gemm.ref import grouped_gemm_ref
from repro.kernels.ssm_scan import ops as ssm
from repro.kernels.ssm_scan.ref import ssm_scan_ref
from repro.kernels.rglru_scan import ops as lru
from repro.kernels.rglru_scan.ref import rglru_scan_ref


def _ok(a, b, tol=3e-2):
    d = float(jnp.max(jnp.abs(jnp.asarray(a, jnp.float32)
                              - jnp.asarray(b, jnp.float32))))
    s = float(jnp.max(jnp.abs(jnp.asarray(b, jnp.float32)))) + 1e-9
    return d / s < tol


def main():
    ks = jax.random.split(jax.random.key(0), 8)
    B, S, H, K, hd = 1, 256, 4, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32)
    us = timeit(lambda: jax.block_until_ready(
        fa.flash_attention(q, k, v, block_q=64, block_k=64,
                           interpret=True)), n=3)
    ok = _ok(fa.flash_attention(q, k, v, block_q=64, block_k=64,
                                interpret=True),
             flash_attention_ref(q, k, v))
    emit("kernel_flash_attention", us, f"allclose_vs_ref={ok}")

    qd = jax.random.normal(ks[3], (2, H, hd), jnp.float32)
    us = timeit(lambda: jax.block_until_ready(
        da.decode_attention(qd, k[:1].repeat(2, 0), v[:1].repeat(2, 0),
                            pos=jnp.int32(100), window=S, block_k=64,
                            interpret=True)), n=3)
    ok = _ok(da.decode_attention(qd, k[:1].repeat(2, 0), v[:1].repeat(2, 0),
                                 pos=jnp.int32(100), window=S, block_k=64,
                                 interpret=True),
             decode_attention_ref(qd, k[:1].repeat(2, 0),
                                  v[:1].repeat(2, 0), pos=100, window=S))
    emit("kernel_decode_attention", us, f"allclose_vs_ref={ok}")

    x = jax.random.normal(ks[4], (4, 64, 64), jnp.float32)
    w = jax.random.normal(ks[5], (4, 64, 64), jnp.float32)
    us = timeit(lambda: jax.block_until_ready(
        gg.grouped_gemm(x, w, block_m=32, block_n=32, block_k=32,
                        interpret=True)), n=3)
    ok = _ok(gg.grouped_gemm(x, w, block_m=32, block_n=32, block_k=32,
                             interpret=True), grouped_gemm_ref(x, w))
    emit("kernel_grouped_gemm", us, f"allclose_vs_ref={ok}")

    Bm, Sm, Din, N = 1, 64, 64, 8
    dt = jax.nn.softplus(jax.random.normal(ks[6], (Bm, Sm, Din)))
    A = -jnp.exp(jax.random.normal(ks[7], (Din, N)) * 0.3)
    B_ = jax.random.normal(ks[0], (Bm, Sm, N))
    C_ = jax.random.normal(ks[1], (Bm, Sm, N))
    xm = jax.random.normal(ks[2], (Bm, Sm, Din))
    us = timeit(lambda: jax.block_until_ready(
        ssm.ssm_scan(dt, A, B_, C_, xm, block_d=32, chunk=16,
                     interpret=True)[0]), n=3)
    ok = _ok(ssm.ssm_scan(dt, A, B_, C_, xm, block_d=32, chunk=16,
                          interpret=True)[0],
             ssm_scan_ref(dt, A, B_, C_, xm)[0])
    emit("kernel_ssm_scan", us, f"allclose_vs_ref={ok}")

    a = jax.nn.sigmoid(jax.random.normal(ks[3], (2, 64, 64)))
    bb = jax.random.normal(ks[4], (2, 64, 64))
    h0 = jax.random.normal(ks[5], (2, 64))
    us = timeit(lambda: jax.block_until_ready(
        lru.rglru_scan(a, bb, h0, block_w=32, chunk=16,
                       interpret=True)[0]), n=3)
    ok = _ok(lru.rglru_scan(a, bb, h0, block_w=32, chunk=16,
                            interpret=True)[0],
             rglru_scan_ref(a, bb, h0)[0])
    emit("kernel_rglru_scan", us, f"allclose_vs_ref={ok}")


if __name__ == "__main__":
    main()
