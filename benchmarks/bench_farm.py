"""ZP-Farm throughput: N subsystem boards through the FarmManager vs the
same boards run serially (a one-slot farm — identical plumbing, no
concurrency). The farm number is the paper's board-farm claim: every
board's window dispatches before any board's previous window is fetched,
so each board's host drain overlaps every board's in-flight compute.
Also records that eviction + requeue preserves verified outputs, and the
async-vs-lockstep head-of-line number: per-slot dispatcher threads vs the
single round-robin host thread, with and without one synthetic slow slot
(boards modeled as jit compute + a per-window service delay — in lockstep
the slow board's delay serializes into EVERY board's round; in async it
costs only its own pipeline)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_smoke_config
from repro.core.coemu import _stack_on_device, subsystem_boards
from repro.core.schedule import iter_windows
from repro.farm import FarmJob, FarmManager
from repro.models import build_model
from repro.models.runtime import Runtime
from repro.utils import dtype_of

GROUP = 2


def _run(boards, slots: int, force_evict=None, sinks=None):
    mgr = FarmManager(slots=slots, evict_stragglers=False)
    for i, (engine, state, x_ins, _, _) in enumerate(boards):
        name = f"board{i}"
        mgr.submit(FarmJob(
            name=name, engine=engine, state=state,
            windows=list(iter_windows(x_ins, GROUP)), shell={},
            stack_fn=_stack_on_device,
            on_drain=sinks[name] if sinks else None))
    if force_evict:
        mgr.force_evict(force_evict)
    return mgr.run()


def main():
    cfg = get_smoke_config("recurrentgemma-2b")   # 3+ extractable layers
    model = build_model(cfg, Runtime())
    params = model.init(jax.random.key(0))
    B, S, n_steps = 2, 16, 8
    xs = [jax.random.normal(jax.random.key(i), (B, S, cfg.d_model))
          .astype(dtype_of(cfg.dtype)) for i in range(n_steps)]
    pos = jnp.tile(jnp.arange(S, dtype=jnp.int32)[None], (B, 1))
    boards = subsystem_boards(params, cfg, Runtime(), xs, pos,
                              layer_idxs=[0, 1, 2])
    total_steps = len(boards) * n_steps

    _run(boards, slots=1)                       # compile every board
    _run(boards, slots=len(boards))
    # interleaved A/B pairs: this shared CPU drifts enough between
    # measurement blocks to swing a back-to-back comparison either way
    ser, farm = [], []
    for _ in range(7):
        t0 = time.perf_counter()
        _run(boards, slots=1)
        ser.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        _run(boards, slots=len(boards))
        farm.append(time.perf_counter() - t0)
    us_serial = sorted(ser)[len(ser) // 2] * 1e6
    us_farm = sorted(farm)[len(farm) // 2] * 1e6
    won = sum(1 for a, b in zip(ser, farm) if a > b)
    sps_serial = total_steps / (us_serial / 1e6)
    sps_farm = total_steps / (us_farm / 1e6)
    emit("farm_serial", us_serial / total_steps,
         f"boards={len(boards)}|slots=1|steps_per_s={sps_serial:.0f}")
    emit("farm_manager", us_farm / total_steps,
         f"boards={len(boards)}|slots={len(boards)}"
         f"|steps_per_s={sps_farm:.0f}"
         f"|farm_vs_serial={us_serial / us_farm:.2f}x"
         f"|pairs_won={won}/{len(ser)}")

    # eviction + requeue must preserve every board's verified outputs
    def collect(which):
        sinks = {f"board{i}": [] for i in range(len(boards))}
        wrapped = {n: (lambda p, r, y, acc=acc: acc.append(np.asarray(y)))
                   for n, acc in sinks.items()}
        rep = _run(boards, slots=len(boards), force_evict=which,
                   sinks=wrapped)
        return sinks, rep

    base, _ = collect(None)
    evicted, rep = collect("board1")
    preserved = all(
        len(base[n]) == len(evicted[n])
        and all(np.array_equal(a, b)
                for a, b in zip(base[n], evicted[n]))
        for n in base)
    emit("farm_evict_requeue", 0.0,
         f"evictions={len(rep['telemetry']['evictions'])}"
         f"|requeues={rep['jobs']['board1']['requeues']}"
         f"|outputs_preserved={preserved}")

    bench_async_vs_lockstep()


# ------------------------------------------------- async vs lockstep -------
@jax.jit
def _delay_body(state, stack):
    return state + jnp.sum(stack), stack * 2.0


def _delay_engine(delay_s: float):
    """A board with a fixed per-window service time: the sleep models the
    board's response latency (releases the GIL, like a real device wait),
    the jit body keeps a real dispatch in the loop."""
    def engine(state, shell, stack):
        time.sleep(delay_s)
        s, ys = _delay_body(state, stack)
        return s, shell, ys
    return engine


def _run_delay_farm(mode: str, delays, n_windows: int = 6):
    mgr = FarmManager(slots=len(delays), mode=mode,
                      evict_stragglers=False)   # measure head-of-line
    sinks = {}                                  # blocking, not eviction
    for i, d in enumerate(delays):
        name = f"board{i}"
        sinks[name] = []
        mgr.submit(FarmJob(
            name=name, engine=_delay_engine(d),
            windows=[[np.float32(i * 100 + w)] for w in range(n_windows)],
            state=jnp.float32(0), shell={},
            stack_fn=lambda it: jnp.asarray(np.stack(it)),
            on_drain=(lambda p, r, y, n=name: sinks[n].append(
                np.asarray(y)))))
    t0 = time.perf_counter()
    mgr.run()
    return time.perf_counter() - t0, sinks


def bench_async_vs_lockstep():
    """Head-of-line blocking A/B: 3 virtual slots, 6 windows per board.
    Slow case: one board at 60ms/window vs two at 30ms — lockstep rounds
    cost the SUM (120ms), async rounds cost the MAX (60ms), so the ideal
    speedup is 2.0x. Uniform case: all boards at 30ms — async still wins
    (rounds overlap entirely, ideal 3x) and must at minimum not regress.
    Outputs must be bit-identical across modes in both cases (the
    lockstep-as-oracle contract)."""
    slow = [0.03, 0.03, 0.06]
    uniform = [0.03, 0.03, 0.03]
    n_windows = 6
    steps = n_windows * len(slow)

    results = {}
    identical = True
    for case, delays in (("slowslot", slow), ("uniform", uniform)):
        outs = {}
        for mode in ("lockstep", "async"):
            _run_delay_farm(mode, delays)           # jit warmup
            ts = []
            for _ in range(3):
                dt, sinks = _run_delay_farm(mode, delays)
                ts.append(dt)
            results[(case, mode)] = sorted(ts)[len(ts) // 2]
            outs[mode] = sinks
        identical = identical and all(
            len(outs["lockstep"][n]) == len(outs["async"][n])
            and all(np.array_equal(a, b)
                    for a, b in zip(outs["lockstep"][n], outs["async"][n]))
            for n in outs["lockstep"])

    slow_x = results[("slowslot", "lockstep")] / results[("slowslot",
                                                          "async")]
    uni_x = results[("uniform", "lockstep")] / results[("uniform", "async")]
    emit("farm_lockstep_slowslot",
         results[("slowslot", "lockstep")] * 1e6 / steps,
         "slots=3|delays=30/30/60ms|mode=lockstep")
    emit("farm_async_slowslot",
         results[("slowslot", "async")] * 1e6 / steps,
         "slots=3|delays=30/30/60ms|mode=async")
    emit("farm_async_vs_lockstep",
         results[("slowslot", "async")] * 1e6 / steps,
         f"slots=3|windows={n_windows}"
         f"|slowslot_speedup={slow_x:.2f}x"
         f"|uniform_speedup={uni_x:.2f}x"
         f"|bit_identical={identical}")


if __name__ == "__main__":
    main()
