"""ZP-Farm throughput: N subsystem boards through the FarmManager vs the
same boards run serially (a one-slot farm — identical plumbing, no
concurrency). The farm number is the paper's board-farm claim: every
board's window dispatches before any board's previous window is fetched,
so each board's host drain overlaps every board's in-flight compute.
Also records that eviction + requeue preserves verified outputs."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.configs import get_smoke_config
from repro.core.coemu import _stack_on_device, subsystem_boards
from repro.core.schedule import iter_windows
from repro.farm import FarmJob, FarmManager
from repro.models import build_model
from repro.models.runtime import Runtime
from repro.utils import dtype_of

GROUP = 2


def _run(boards, slots: int, force_evict=None, sinks=None):
    mgr = FarmManager(slots=slots, evict_stragglers=False)
    for i, (engine, x_ins, _) in enumerate(boards):
        name = f"board{i}"
        mgr.submit(FarmJob(
            name=name, engine=engine,
            windows=list(iter_windows(x_ins, GROUP)), shell={},
            stack_fn=_stack_on_device,
            on_drain=sinks[name] if sinks else None))
    if force_evict:
        mgr.force_evict(force_evict)
    return mgr.run()


def main():
    cfg = get_smoke_config("recurrentgemma-2b")   # 3+ extractable layers
    model = build_model(cfg, Runtime())
    params = model.init(jax.random.key(0))
    B, S, n_steps = 2, 16, 8
    xs = [jax.random.normal(jax.random.key(i), (B, S, cfg.d_model))
          .astype(dtype_of(cfg.dtype)) for i in range(n_steps)]
    pos = jnp.tile(jnp.arange(S, dtype=jnp.int32)[None], (B, 1))
    boards = subsystem_boards(params, cfg, Runtime(), xs, pos,
                              layer_idxs=[0, 1, 2])
    total_steps = len(boards) * n_steps

    _run(boards, slots=1)                       # compile every board
    us_serial = timeit(lambda: _run(boards, slots=1), n=5)
    us_farm = timeit(lambda: _run(boards, slots=len(boards)), n=5)
    sps_serial = total_steps / (us_serial / 1e6)
    sps_farm = total_steps / (us_farm / 1e6)
    emit("farm_serial", us_serial / total_steps,
         f"boards={len(boards)}|slots=1|steps_per_s={sps_serial:.0f}")
    emit("farm_manager", us_farm / total_steps,
         f"boards={len(boards)}|slots={len(boards)}"
         f"|steps_per_s={sps_farm:.0f}"
         f"|farm_vs_serial={us_serial / us_farm:.2f}x")

    # eviction + requeue must preserve every board's verified outputs
    def collect(which):
        sinks = {f"board{i}": [] for i in range(len(boards))}
        wrapped = {n: (lambda p, r, y, acc=acc: acc.append(np.asarray(y)))
                   for n, acc in sinks.items()}
        rep = _run(boards, slots=len(boards), force_evict=which,
                   sinks=wrapped)
        return sinks, rep

    base, _ = collect(None)
    evicted, rep = collect("board1")
    preserved = all(
        len(base[n]) == len(evicted[n])
        and all(np.array_equal(a, b)
                for a, b in zip(base[n], evicted[n]))
        for n in base)
    emit("farm_evict_requeue", 0.0,
         f"evictions={len(rep['telemetry']['evictions'])}"
         f"|requeues={rep['jobs']['board1']['requeues']}"
         f"|outputs_preserved={preserved}")


if __name__ == "__main__":
    main()
