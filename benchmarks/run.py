"""Benchmark harness: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV and writes the same rows to
``BENCH_results.json`` (machine-readable, for cross-PR perf tracking).
Results MERGE into the existing file by default — an unfiltered run no
longer clobbers entries it did not re-measure (e.g. a bench module that
failed this run, or rows written by another harness); pass ``--fresh`` to
rewrite the file from only this run's rows. Run:

  PYTHONPATH=src python -m benchmarks.run            # all benches (merge)
  PYTHONPATH=src python -m benchmarks.run sampling   # substring filter
  PYTHONPATH=src python -m benchmarks.run --fresh    # clobber stale rows
"""
from __future__ import annotations

import sys
import traceback

from benchmarks.common import write_results

MODULES = [
    "benchmarks.bench_kernels",       # per-kernel us/call + allclose
    "benchmarks.bench_tco",           # Table I  — TCO model
    "benchmarks.bench_stall_stack",   # Fig. 7   — cycle stacks
    "benchmarks.bench_sampling",      # Fig. 11/12 — interval sweep
    "benchmarks.bench_coverage",      # Fig. 13  — coverage overhead
    "benchmarks.bench_panicroom",     # Table II — portability
    "benchmarks.bench_coemu",         # §IV-A    — verify throughput
    "benchmarks.bench_farm",          # ZP-Farm  — farm-vs-serial boards
    "benchmarks.bench_lanes",         # ZP-Farm  — lane-batched boards
    "benchmarks.bench_scope",         # ZP-Scope — instrumentation overhead
]


def main() -> None:
    argv = sys.argv[1:]
    fresh = "--fresh" in argv
    filters = [a for a in argv if not a.startswith("-")]
    mods = [m for m in MODULES
            if not filters or any(f in m for f in filters)]
    print("name,us_per_call,derived")
    failed = []
    for mod_name in mods:
        try:
            mod = __import__(mod_name, fromlist=["main"])
            mod.main()
        except Exception:
            traceback.print_exc()
            failed.append(mod_name)
    write_results(merge=not fresh)
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
