"""Benchmark harness: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV and writes the same rows to
``BENCH_results.json`` (machine-readable, for cross-PR perf tracking). Run:

  PYTHONPATH=src python -m benchmarks.run            # all benches
  PYTHONPATH=src python -m benchmarks.run sampling   # substring filter
"""
from __future__ import annotations

import sys
import traceback

from benchmarks.common import write_results

MODULES = [
    "benchmarks.bench_kernels",       # per-kernel us/call + allclose
    "benchmarks.bench_tco",           # Table I  — TCO model
    "benchmarks.bench_stall_stack",   # Fig. 7   — cycle stacks
    "benchmarks.bench_sampling",      # Fig. 11/12 — interval sweep
    "benchmarks.bench_coverage",      # Fig. 13  — coverage overhead
    "benchmarks.bench_panicroom",     # Table II — portability
    "benchmarks.bench_coemu",         # §IV-A    — verify throughput
    "benchmarks.bench_farm",          # ZP-Farm  — farm-vs-serial boards
]


def main() -> None:
    filters = sys.argv[1:]
    mods = [m for m in MODULES
            if not filters or any(f in m for f in filters)]
    print("name,us_per_call,derived")
    failed = []
    for mod_name in mods:
        try:
            mod = __import__(mod_name, fromlist=["main"])
            mod.main()
        except Exception:
            traceback.print_exc()
            failed.append(mod_name)
    write_results(merge=bool(filters))
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
