"""Paper Fig. 7: cycle-stack (stall-stack) breakdown. Two modalities:
live host attribution (device/host/data) on a real smoke train run, and the
model-mode compute/memory/collective stack from the roofline records."""
from __future__ import annotations

import glob
import json

from benchmarks.common import emit
from repro.configs import get_smoke_config
from repro.core import Profiler
from repro.models import build_model
from repro.models.runtime import Runtime
from repro.train.loop import LoopConfig, train_loop


def main():
    cfg = get_smoke_config("glm4-9b")
    model = build_model(cfg, Runtime(taps=frozenset({"commits",
                                                     "coverage"})))
    out = train_loop(model, LoopConfig(steps=10, batch=4, seq=32,
                                       sample_interval=1))
    tot = sum(out["profile"].values()) or 1.0
    frac = {k: v / tot for k, v in out["profile"].items()}
    emit("fig7_live_stack", tot / 10 * 1e6,
         "|".join(f"{k}={v:.3f}" for k, v in sorted(frac.items())))

    # model-mode stacks from the roofline sweep (per-cell dominant terms)
    for f in sorted(glob.glob("experiments/roofline/*.json"))[:40]:
        r = json.load(open(f))
        if r.get("status") != "ok":
            continue
        s = Profiler.model_stack([{ "compute_s": r["compute_s"],
                                    "memory_s": r["memory_s"],
                                    "collective_s": r["collective_s"]}])
        fr = s.fractions()
        emit(f"fig7_model_stack_{r['arch']}_{r['shape']}",
             r["step_time_bound_s"] * 1e6,
             "|".join(f"{k}={v:.3f}" for k, v in sorted(fr.items())))


if __name__ == "__main__":
    main()
