"""Paper Table II: PanicRoom portability — the SAME benchmark (file I/O +
a kernel workload) under 'sim' (interpret Pallas) and 'hw' (jit XLA), plus
the BSP's LoC count (the paper reports 20 vs 7k-14k for proxy solutions)."""
from __future__ import annotations

import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.panicroom import run_benchmark


def _bench(bsp, platform):
    """Writes a matrix to the FS, reads it back, multiplies via the grouped
    GEMM kernel (interpret on 'sim', jit on 'hw'), writes the result."""
    from repro.kernels.grouped_gemm import ops as gg
    rng = np.random.default_rng(0)
    a = rng.standard_normal((2, 32, 32), dtype=np.float32)
    fd = bsp.open("a.bin", "w")
    bsp.write(fd, a.tobytes())
    bsp.close(fd)

    fd = bsp.open("a.bin", "r")
    back = np.frombuffer(bsp.read(fd), dtype=np.float32).reshape(2, 32, 32)
    bsp.close(fd)
    if platform == "sim":
        # simulation: the Pallas kernel body interpreted on CPU
        out = gg.grouped_gemm(jnp.asarray(back), jnp.asarray(back),
                              block_m=16, block_n=16, block_k=16,
                              interpret=True)
    else:
        # "hardware": the jit-compiled XLA executable
        out = jax.jit(lambda a, b: jnp.einsum("emk,ekn->emn", a, b))(
            jnp.asarray(back), jnp.asarray(back))
    fd = bsp.open("out.bin", "w")
    bsp.write(fd, np.asarray(out).tobytes())
    bsp.close(fd)
    bsp.puts(f"checksum={float(jnp.sum(out)):.3f}")
    return {"checksum": float(jnp.sum(out))}


def main():
    sim = run_benchmark(_bench, "sim")
    hw = run_benchmark(_bench, "hw")
    assert abs(sim["result"]["checksum"] - hw["result"]["checksum"]) < 1e-2
    assert sim["stdout"].split("=")[0] == hw["stdout"].split("=")[0]
    for r in (sim, hw):
        emit(f"table2_panicroom_{r['platform']}", r["wall_s"] * 1e6,
             f"syscalls={sum(r['syscalls'].values())}"
             f"|identical_output={sim['result'] == hw['result']}")
    # BSP LoC (the portability claim)
    root = pathlib.Path(__file__).resolve().parents[1] / "src/repro/panicroom"
    loc = sum(1 for f in root.glob("*.py") for l in open(f)
              if l.strip() and not l.strip().startswith("#"))
    emit("table2_panicroom_loc", 0.0, f"bsp_loc={loc}")


if __name__ == "__main__":
    main()
