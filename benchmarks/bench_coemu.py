"""Paper §IV-A: co-emulation verification throughput (commits/s) — DUT
(bf16, optimized) step-locked against the golden oracle (f32 reference)."""
from __future__ import annotations

import dataclasses
import time

import jax

from benchmarks.common import emit
from repro.configs import get_smoke_config
from repro.core import CoEmulator
from repro.data import make_batch_fn
from repro.models import build_model
from repro.models.runtime import Runtime
from repro.train import make_train_step, init_state


def main():
    cfg = get_smoke_config("glm4-9b")
    cfg_f32 = dataclasses.replace(cfg, dtype="float32")
    taps = frozenset({"commits"})
    dut_model = build_model(cfg, Runtime(taps=taps, remat="dots"))
    orc_model = build_model(cfg_f32, Runtime(taps=taps))
    dut = jax.jit(make_train_step(dut_model))
    orc = jax.jit(make_train_step(orc_model))
    s_dut = init_state(dut_model, jax.random.key(0))
    s_orc = init_state(orc_model, jax.random.key(0))
    batchf = make_batch_fn(cfg, 2, 32)
    batches = [{k: jax.numpy.asarray(v) for k, v in batchf(i).items()}
               for i in range(8)]

    emu = CoEmulator(dut, orc, rtol=0.3)
    rep = emu.verify(s_dut, s_orc, batches)               # compile both sides
    group = len(batches) // 4
    rep_g = emu.verify(s_dut, s_orc, batches, group_size=group)  # compile

    # interleave step-locked / grouped pairs: on a shared CPU, timing the
    # two modes in separate blocks lets machine drift masquerade as a
    # grouped regression (this is exactly what the pre-PR-4 0.66x was);
    # pairs_won is the drift-robust signal, the median ratio the magnitude
    step_ts, grp_ts = [], []
    for _ in range(7):
        t0 = time.perf_counter()
        emu.verify(s_dut, s_orc, batches)
        step_ts.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        emu.verify(s_dut, s_orc, batches, group_size=group)
        grp_ts.append(time.perf_counter() - t0)
    us = sorted(step_ts)[len(step_ts) // 2] * 1e6
    us_g = sorted(grp_ts)[len(grp_ts) // 2] * 1e6
    won = sum(1 for a, b in zip(step_ts, grp_ts) if a > b)
    dt, dt_g = us / 1e6, us_g / 1e6
    commits = rep.steps * cfg.num_layers
    emit("coemu_verify", us / rep.steps,
         f"commits_per_s={commits/dt:.0f}|diverged={rep.diverged}"
         f"|max_rel_err={rep.max_rel_err:.2e}")
    emit("coemu_verify_grouped", us_g / rep_g.steps,
         f"group={group}|commits_per_s={commits/dt_g:.0f}"
         f"|speedup={dt/dt_g:.2f}x|pairs_won={won}/{len(step_ts)}"
         f"|diverged={rep_g.diverged}")

    det = CoEmulator.determinism(dut, s_dut, batches[0])
    emit("coemu_determinism", 0.0, f"bitwise_reproducible={det}")

    # scheduler overlap A/B: grouped verify WITH the WindowScheduler's
    # overlapped DUT/oracle dispatch (back-to-back async windows, window
    # i's blocking fetch deferred until window i+1 is in flight) vs the
    # serial baseline (DUT window synced before the oracle dispatches, one
    # window fetched before the next dispatches — the pre-scheduler 2-
    # serial-syncs loop). Measured on granite-8b, whose per-op sizes leave
    # the backend headroom for concurrent DUT/oracle windows; pairs_won
    # (interleaved A/B pairs favoring overlap) is the drift-robust signal
    # on this shared CPU, the median ratio the magnitude.
    cfg2 = get_smoke_config("granite-8b")
    cfg2_f32 = dataclasses.replace(cfg2, dtype="float32")
    dut2_model = build_model(cfg2, Runtime(taps=taps, remat="dots"))
    orc2_model = build_model(cfg2_f32, Runtime(taps=taps))
    emu2 = CoEmulator(jax.jit(make_train_step(dut2_model)),
                      jax.jit(make_train_step(orc2_model)), rtol=0.3)
    s2_dut = init_state(dut2_model, jax.random.key(0))
    s2_orc = init_state(orc2_model, jax.random.key(0))
    batchf2 = make_batch_fn(cfg2, 2, 32)
    batches2 = [{k: jax.numpy.asarray(v) for k, v in batchf2(i).items()}
                for i in range(8)]
    emu2.verify(s2_dut, s2_orc, batches2, group_size=2)   # compile
    # interleave the A/B pairs so shared-CPU drift between measurement
    # blocks cannot masquerade as (or mask) the overlap effect
    ser, ovl = [], []
    for _ in range(7):
        t0 = time.perf_counter()
        emu2.verify(s2_dut, s2_orc, batches2, group_size=2, overlap=False)
        ser.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        emu2.verify(s2_dut, s2_orc, batches2, group_size=2, overlap=True)
        ovl.append(time.perf_counter() - t0)
    us_serial = sorted(ser)[len(ser) // 2] * 1e6
    us_ovl = sorted(ovl)[len(ovl) // 2] * 1e6
    won = sum(1 for a, b in zip(ser, ovl) if a > b)
    emit("coemu_grouped_serial_baseline", us_serial / len(batches2),
         "arch=granite-8b|group=2|overlap=False")
    emit("coemu_grouped_overlapped", us_ovl / len(batches2),
         f"arch=granite-8b|group=2|overlap=True"
         f"|overlap_speedup_vs_serial={us_serial/us_ovl:.2f}x"
         f"|pairs_won={won}/{len(ser)}")


if __name__ == "__main__":
    main()
