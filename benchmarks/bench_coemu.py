"""Paper §IV-A: co-emulation verification throughput (commits/s) — DUT
(bf16, optimized) step-locked against the golden oracle (f32 reference)."""
from __future__ import annotations

import dataclasses
import time

import jax

from benchmarks.common import emit
from repro.configs import get_smoke_config
from repro.core import CoEmulator
from repro.data import make_batch_fn
from repro.models import build_model
from repro.models.runtime import Runtime
from repro.train import make_train_step, init_state


def main():
    cfg = get_smoke_config("glm4-9b")
    cfg_f32 = dataclasses.replace(cfg, dtype="float32")
    taps = frozenset({"commits"})
    dut_model = build_model(cfg, Runtime(taps=taps, remat="dots"))
    orc_model = build_model(cfg_f32, Runtime(taps=taps))
    dut = jax.jit(make_train_step(dut_model))
    orc = jax.jit(make_train_step(orc_model))
    s_dut = init_state(dut_model, jax.random.key(0))
    s_orc = init_state(orc_model, jax.random.key(0))
    batchf = make_batch_fn(cfg, 2, 32)
    batches = [{k: jax.numpy.asarray(v) for k, v in batchf(i).items()}
               for i in range(6)]

    emu = CoEmulator(dut, orc, rtol=0.3)
    emu.verify(s_dut, s_orc, batches[:1])                 # compile both sides
    t0 = time.perf_counter()
    rep = emu.verify(s_dut, s_orc, batches)
    dt = time.perf_counter() - t0
    commits = rep.steps * cfg.num_layers
    emit("coemu_verify", dt / rep.steps * 1e6,
         f"commits_per_s={commits/dt:.0f}|diverged={rep.diverged}"
         f"|max_rel_err={rep.max_rel_err:.2e}")

    # group-locked: one scan-fused dispatch per side per window
    group = len(batches)
    emu.verify(s_dut, s_orc, batches, group_size=group)   # compile
    t0 = time.perf_counter()
    rep_g = emu.verify(s_dut, s_orc, batches, group_size=group)
    dt_g = time.perf_counter() - t0
    emit("coemu_verify_grouped", dt_g / rep_g.steps * 1e6,
         f"group={group}|commits_per_s={commits/dt_g:.0f}"
         f"|speedup={dt/dt_g:.2f}x|diverged={rep_g.diverged}")

    det = CoEmulator.determinism(dut, s_dut, batches[0])
    emit("coemu_determinism", 0.0, f"bitwise_reproducible={det}")


if __name__ == "__main__":
    main()
