"""Co-emulation case study (paper §IV-B workflow): verify an optimized DUT
against the f32 golden model through the commit stream, then inject a fault
and watch the verifier localize it to the exact layer.

  PYTHONPATH=src python examples/coemu_verify.py
"""
import dataclasses

import jax

from repro.configs import get_smoke_config
from repro.core import CoEmulator
from repro.core.coemu import inject_fault
from repro.data import make_batch_fn
from repro.models import build_model
from repro.models.runtime import Runtime
from repro.train import make_train_step, init_state


def main():
    cfg = get_smoke_config("glm4-9b")
    taps = frozenset({"commits"})
    dut_model = build_model(cfg, Runtime(taps=taps, remat="dots"))
    orc_model = build_model(dataclasses.replace(cfg, dtype="float32"),
                            Runtime(taps=taps))
    dut = jax.jit(make_train_step(dut_model))
    orc = jax.jit(make_train_step(orc_model))
    s_dut = init_state(dut_model, jax.random.key(0))
    s_orc = init_state(orc_model, jax.random.key(0))
    batchf = make_batch_fn(cfg, 2, 32)
    batches = [{k: jax.numpy.asarray(v) for k, v in batchf(i).items()}
               for i in range(4)]

    emu = CoEmulator(dut, orc, rtol=0.3)
    print("clean run:", emu.verify(s_dut, s_orc, batches).summary())
    print("determinism:",
          CoEmulator.determinism(dut, s_dut, batches[0]))

    for layer in (0, 1):
        s_bad = {**s_dut, "params": inject_fault(s_dut["params"], cfg, layer)}
        rep = emu.verify(s_bad, s_orc, batches[:1])
        print(f"fault@layer{layer}:", rep.summary())
        assert rep.first.layer == layer


if __name__ == "__main__":
    main()
