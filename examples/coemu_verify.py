"""Co-emulation case study (paper §IV-B workflow): verify an optimized DUT
against the f32 golden model through the commit stream, then inject a fault
and watch the verifier localize it to the exact layer.

  PYTHONPATH=src python examples/coemu_verify.py [--steps 4]
"""
import argparse
import dataclasses

import jax

from repro.configs import get_smoke_config
from repro.core import CoEmulator
from repro.core.coemu import inject_fault
from repro.data import make_batch_fn
from repro.models import build_model
from repro.models.runtime import Runtime
from repro.train import make_train_step, init_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=4,
                    help="verification step budget (CI smoke uses 2)")
    args = ap.parse_args()
    cfg = get_smoke_config("glm4-9b")
    taps = frozenset({"commits"})
    dut_model = build_model(cfg, Runtime(taps=taps, remat="dots"))
    orc_model = build_model(dataclasses.replace(cfg, dtype="float32"),
                            Runtime(taps=taps))
    dut = jax.jit(make_train_step(dut_model))
    orc = jax.jit(make_train_step(orc_model))
    s_dut = init_state(dut_model, jax.random.key(0))
    s_orc = init_state(orc_model, jax.random.key(0))
    batchf = make_batch_fn(cfg, 2, 32)
    batches = [{k: jax.numpy.asarray(v) for k, v in batchf(i).items()}
               for i in range(args.steps)]

    emu = CoEmulator(dut, orc, rtol=0.3)
    print("clean run:", emu.verify(s_dut, s_orc, batches).summary())
    if len(batches) > 1:
        rep = emu.verify(s_dut, s_orc, batches,
                         group_size=max(2, len(batches) // 2))
        print("group-locked (scheduler-overlapped):", rep.summary())
    print("determinism:",
          CoEmulator.determinism(dut, s_dut, batches[0]))

    # fault localization: verify the faulted DUT against the CLEAN DUT so
    # the commit stream carries pure fault signal (the bf16-vs-f32 oracle
    # gap sits near rtol and would blur the margin)
    emu_fault = CoEmulator(dut, dut, rtol=5e-2)
    for layer in (0, 1):
        s_bad = {**s_dut, "params": inject_fault(s_dut["params"], cfg, layer)}
        rep = emu_fault.verify(s_bad, s_dut, batches[:1])
        print(f"fault@layer{layer}:", rep.summary())
        assert rep.diverged and rep.first.layer == layer


if __name__ == "__main__":
    main()
