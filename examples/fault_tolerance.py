"""ZP-Farm fault-tolerance demo (paper §IV-A): a training job is killed
mid-run (simulated preemption), a fresh process resumes from the last
atomic checkpoint, and the deterministic data pipeline replays the stream
so the loss trajectory continues exactly.

  PYTHONPATH=src python examples/fault_tolerance.py
"""
import tempfile

import numpy as np

from repro.configs import get_smoke_config
from repro.core import Watchdog
from repro.models import build_model
from repro.models.runtime import Runtime
from repro.train.loop import LoopConfig, train_loop


class Preemption(Exception):
    pass


def main():
    cfg = get_smoke_config("granite-8b")

    def model():
        return build_model(cfg, Runtime(taps=frozenset({"commits"})))

    with tempfile.TemporaryDirectory() as d, \
            tempfile.TemporaryDirectory() as d_ref:
        lc = dict(batch=2, seq=32, checkpoint_every=5, sample_interval=5,
                  checkpoint_dir=d)

        # reference: uninterrupted 15-step run (its own checkpoint dir)
        ref = train_loop(model(), LoopConfig(
            steps=15, **{**lc, "checkpoint_dir": d_ref}), resume=False)

        # victim: same run, "preempted" after step 8 (watchdog would flag
        # the dead worker and the scheduler restarts the job)
        class StopAt8:
            n = 0
        try:
            def bomb(step, records):
                StopAt8.n = step
                if step >= 8:
                    raise Preemption()
            train_loop(model(), LoopConfig(steps=15, **lc),
                       on_drain=bomb, resume=False)
        except Preemption:
            print(f"preempted at step {StopAt8.n} "
                  f"(last checkpoint: step 5)")

        wd = Watchdog(timeout_s=0.0)
        wd.heartbeat("victim")
        assert wd.should_restart()        # the farm notices

        # restart: fresh process restores step-5 checkpoint, replays 5..14
        resumed = train_loop(model(), LoopConfig(steps=15, **lc),
                             resume=True)
        tail = ref["losses"][5:]
        np.testing.assert_allclose(resumed["losses"], tail,
                                   rtol=1e-5, atol=1e-5)
        print(f"resumed {len(resumed['losses'])} steps; trajectory matches "
              f"the uninterrupted run exactly "
              f"(final loss {resumed['losses'][-1]:.4f} == "
              f"{tail[-1]:.4f})")


if __name__ == "__main__":
    main()
