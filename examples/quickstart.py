"""Quickstart: build an architecture, train a few steps with the full
P-Shell co-emulation stack (fused clock-gated windows through the core
WindowScheduler), inspect commits/coverage, generate tokens.

  PYTHONPATH=src python examples/quickstart.py [--arch glm4-9b]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_smoke_config
from repro.core import (PShell, default_shell_config, make_ingest,
                        CoverageMap)
from repro.data import SyntheticPipeline
from repro.models import build_model
from repro.models.runtime import Runtime
from repro.train import make_train_step, make_group_step, init_state
from repro.serve import make_prefill_step, make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b", choices=list(ARCH_IDS))
    ap.add_argument("--steps", type=int, default=8)
    args = ap.parse_args()

    # 1. architecture (reduced config for CPU; full config via --arch on a pod)
    cfg = get_smoke_config(args.arch)
    rt = Runtime(taps=frozenset({"commits", "coverage", "router"}))
    model = build_model(cfg, rt)
    print(f"arch={cfg.name} layers={cfg.num_layers} d={cfg.d_model} "
          f"params={sum(x.size for x in jax.tree.leaves(jax.eval_shape(model.init, jax.random.key(0))))/1e6:.1f}M")

    # 2. train through the core WindowScheduler: each clock-gated window
    # (sample_interval steps) is ONE fused dispatch, and the host drain of
    # window i overlaps window i+1's in-flight compute (DESIGN C2/C3)
    state = init_state(model, jax.random.key(0))
    ingest = make_ingest(cfg)
    shell = PShell(default_shell_config(cfg, sample_interval=2), ingest)
    cov = CoverageMap()
    pipe = SyntheticPipeline(cfg, batch=4, seq=32)

    def on_drain(i, rec):
        cov.update(rec["csrs"])
        commits = rec["fifos"]["commits"]
        losses = rec["metrics"]["loss"]
        print(f"window ..{i}: loss={float(losses[-1]):.3f} "
              f"commits={commits['count']} dropped={commits['dropped']} "
              f"coverage={cov.fraction():.2f}")

    try:
        batches = [next(pipe) for _ in range(args.steps)]
        state, _, _ = shell.run_grouped(
            make_group_step(model, ingest=ingest), state, batches,
            on_drain=on_drain)
    finally:
        pipe.close()

    # 3. serve: prefill a prompt, decode greedily
    params = state["params"]
    prompt = jax.random.randint(jax.random.key(7), (2, 16), 0, cfg.vocab_size)
    b = {"tokens": prompt}
    if cfg.family == "vlm":
        b["patches"] = jnp.zeros((2, cfg.num_patches, cfg.patch_embed_dim),
                                 jnp.bfloat16)
    if cfg.family == "encdec":
        b["frames"] = jnp.zeros((2, cfg.encoder_seq, cfg.d_model),
                                jnp.bfloat16)
    cache, logits = jax.jit(make_prefill_step(model, 64))(params, b)
    serve = jax.jit(make_serve_step(model))
    toks = []
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    for _ in range(8):
        cache, logits = serve(params, cache, tok)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        toks.append(int(tok[0, 0]))
    print("generated:", toks)


if __name__ == "__main__":
    main()
