"""Scale-Down decomposition demo (paper Fig. 5): extract a single block with
its preserved interface, replay captured in-situ traffic bit-identically,
and compare the scanned 'Scale-Up model' against composed subsystems.

  PYTHONPATH=src python examples/scale_down_extraction.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import decompose
from repro.models import build_model


def main():
    for arch in ("recurrentgemma-2b", "falcon-mamba-7b", "glm4-9b"):
        cfg = get_smoke_config(arch)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        x = (jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
             .astype(jnp.bfloat16))
        pos = jnp.broadcast_to(jnp.arange(16, dtype=jnp.int32), (2, 16))

        subsystems = [s for _, s, _ in
                      decompose.iter_layer_params(params, cfg)]
        print(f"\n{arch}: {len(subsystems)} extractable blocks "
              f"({[m for m, _ in cfg.layer_specs]})")
        for layer in range(min(3, cfg.num_layers)):
            rep = decompose.verify_extraction(params, cfg, x, pos,
                                              model.rt, layer)
            print(f"  {rep['subsystem']:26s} bitwise={rep['bitwise_identical']}")
        d = decompose.scanned_vs_unrolled(params, cfg, x, pos, model.rt)
        print(f"  scan-vs-composed rel diff: {d:.2e}")


if __name__ == "__main__":
    main()
