"""End-to-end training driver: ~100M-class model for a few hundred steps on
CPU, with checkpoint/restart, watchdog, coverage and live stall profiling —
the full ZP-Farm host loop (deliverable (b)).

  PYTHONPATH=src python examples/train_e2e.py --steps 300
"""
import argparse
import dataclasses
import json

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.models.runtime import Runtime
from repro.train.loop import LoopConfig, train_loop
from repro.train.optim import OptConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()

    # ~100M-class: widen the granite smoke config
    cfg = dataclasses.replace(
        get_smoke_config("granite-8b"),
        name="granite-100m", num_layers=8, d_model=512, num_heads=8,
        num_kv_heads=4, head_dim=64, d_ff=1408, vocab_size=32768)
    model = build_model(cfg, Runtime(
        taps=frozenset({"commits", "coverage"}), remat="dots"))

    out = train_loop(
        model,
        LoopConfig(steps=args.steps, batch=8, seq=128, sample_interval=10,
                   checkpoint_every=100, checkpoint_dir=args.ckpt),
        OptConfig(lr=3e-4, warmup_steps=50))
    n = len(out["losses"])
    print(json.dumps({
        "params_m": round(cfg.param_count() / 1e6, 1),
        "steps": n,
        "loss_start": sum(out["losses"][:10]) / min(10, n),
        "loss_end": sum(out["losses"][-10:]) / min(10, n),
        "profile_s": out["profile"],
        "coverage": out["coverage"],
    }, indent=1, default=float))


if __name__ == "__main__":
    main()
